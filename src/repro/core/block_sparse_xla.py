"""Gather-based XLA implementation of SLA (no Pallas).

Purpose:
  1. Dry-run / roofline honesty: the dense reference computes the full
     N x N score matrix and masks it, so XLA cost_analysis would report
     *full-attention* FLOPs. This path gathers only the critical KV blocks
     (jnp.take_along_axis over the row LUT), so compiled HLO FLOPs equal
     the true sparse cost — what lands on a real TPU.
  2. A differentiable production path on any backend (autodiff-compatible;
     gather -> scatter-add in the backward).

Sharding note: batch and head axes are kept SEPARATE throughout (no
(B*H,...) flattening) so GSPMD propagates data-axis batch sharding and
model-axis head sharding into every intermediate — flattening them was
measured to replicate the (.., Tm, D, D) linear-branch aggregates on
every device (see EXPERIMENTS.md §Perf iteration log).

The query-row loop runs as a lax.scan over chunks of `chunk` row blocks
(compiles once, keeps the gathered working set small); the chunk body is
rematerialized so the backward does not store gathered KV.
"""
from __future__ import annotations


from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import SLAConfig
from repro.core.plan import SLAPlan
from repro.core.reference import _safe_div

NEG_INF = -1e30


def _gather_blocks(xb: jax.Array, idx: jax.Array) -> jax.Array:
    """xb: (B, H, Tn, bkv, D); idx: (B, H, C, K) -> (B, H, C, K, bkv, D)."""
    b, h, tn, bkv, d = xb.shape
    c, ks = idx.shape[2], idx.shape[3]
    flat = idx.reshape(b, h, c * ks)
    out = jnp.take_along_axis(xb, flat[:, :, :, None, None], axis=2)
    return out.reshape(b, h, c, ks, bkv, d)


def _row_chunk(qc, kg, vg, idxc, cntc, i0, scale, causal, block_q,
               block_kv):
    """Attend one chunk of query-row blocks to their gathered critical
    blocks.

    qc: (B, H, C, bq, D); kg, vg: (B, H, C, K, bkv, D);
    idxc: (B, H, C, K); cntc: (B, H, C); i0: (C,) absolute row-block ids.
    Returns (o (B, H, C, bq, D), lse (B, H, C, bq)).
    """
    s = jnp.einsum("bhcqd,bhckvd->bhcqkv", qc.astype(jnp.float32),
                   kg.astype(jnp.float32)) * scale
    ks = kg.shape[3]
    slot = jnp.arange(ks)
    live = slot[None, None, None, :] < cntc[..., None]  # (B, H, C, K)
    s = jnp.where(live[:, :, :, None, :, None], s, NEG_INF)
    if causal:
        rows = (i0[:, None] * block_q
                + jnp.arange(block_q)[None, :])  # (C, bq)
        cols = (idxc[..., None] * block_kv
                + jnp.arange(block_kv))  # (B, H, C, K, bkv)
        ok = rows[None, None, :, :, None, None] >= \
            cols[:, :, :, None, :, :]
        s = jnp.where(ok, s, NEG_INF)
    b, h, c, bq = s.shape[:4]
    sf = s.reshape(b, h, c, bq, ks * kg.shape[4])
    m = jnp.max(sf, axis=-1, keepdims=True)
    p = jnp.exp(sf - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    vgf = vg.reshape(b, h, c, ks * vg.shape[4], vg.shape[5]) \
        .astype(jnp.float32)
    o = jnp.einsum("bhcqk,bhckd->bhcqd", p / l, vgf)
    lse = (m + jnp.log(l))[..., 0]
    return o, lse


def sparse_component_gather(
    q: jax.Array, k: jax.Array, v: jax.Array,
    lut: jax.Array, counts: jax.Array, cfg: SLAConfig,
    scale: float | None = None, chunk: int = 8,
    row_offset=0,
) -> Tuple[jax.Array, jax.Array]:
    """O^s via LUT gather. q,k,v: (B, H, N, D); lut: (B, H, Tm, K).

    `row_offset` (python int or traced int32 scalar) shifts the
    absolute query-row-block ids used by the causal mask: chunked
    prefill attends a (N = chunk) query span starting at block
    `row_offset` against the full KV bucket, and a TRACED offset keeps
    every chunk index on one compiled graph (DESIGN.md "Chunked
    admission prefill").

    Returns (o_s (B, H, N, D) f32, lse (B, H, N) f32).
    """
    b, h, n, d = q.shape
    scale = (d**-0.5) if scale is None else scale
    bq, bkv = cfg.block_q, cfg.block_kv
    tm = n // bq
    chunk = min(chunk, tm)
    while tm % chunk:
        chunk -= 1
    kb = k.reshape(b, h, -1, bkv, d)
    vb = v.reshape(b, h, -1, bkv, d)
    qc = q.reshape(b, h, tm // chunk, chunk, bq, d)
    lutc = lut.reshape(b, h, tm // chunk, chunk, -1)
    cntc = counts.reshape(b, h, tm // chunk, chunk)

    # The WHOLE body (gather included) is rematerialized: otherwise the
    # scan stacks every step's gathered KV as a backward residual —
    # measured at 5.2 GiB/device x dozens of buffers at the wan2.1 cell.
    @jax.checkpoint
    def body(_, args):
        qi, idxc, cnt, i0 = args
        kg = _gather_blocks(kb, idxc)
        vg = _gather_blocks(vb, idxc)
        o, lse = _row_chunk(qi, kg, vg, idxc, cnt, i0, scale, cfg.causal,
                            bq, bkv)
        return None, (o, lse)

    i0s = (row_offset + jnp.arange(tm)).reshape(tm // chunk, chunk)
    _, (o, lse) = jax.lax.scan(
        body, None,
        (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(lutc, 2, 0),
         jnp.moveaxis(cntc, 2, 0), i0s))
    o = jnp.moveaxis(o, 0, 2).reshape(b, h, n, d)
    lse = jnp.moveaxis(lse, 0, 2).reshape(b, h, n)
    return o, lse


def sla_forward_gather(
    q: jax.Array, k: jax.Array, v: jax.Array,
    qp: jax.Array, kp: jax.Array, plan: SLAPlan, cfg: SLAConfig,
    scale: float | None = None, chunk: int = 8, row_offset=0,
) -> Tuple[jax.Array, jax.Array]:
    """(O^s, O^l) with gather-based sparse part and matmul-aggregated
    linear part. The block structure (row LUT + marginal aggregation
    matrix) comes from the precomputed `plan`. Shapes: (B, H, N, D).
    `row_offset` as in `sparse_component_gather` (the plan's row axis
    then covers only the chunk's query blocks)."""
    b, h, n, d = q.shape
    tn = plan.num_kv_blocks
    o_s, _ = sparse_component_gather(q, k, v, plan.lut, plan.counts, cfg,
                                     scale, chunk, row_offset)

    kpb = kp.astype(jnp.float32).reshape(b, h, tn, cfg.block_kv, d)
    vb = v.astype(jnp.float32).reshape(b, h, tn, cfg.block_kv, d)
    hb = jnp.einsum("bhnkd,bhnke->bhnde", kpb, vb)
    zb = jnp.sum(kpb, axis=-2)
    a = plan.marginal
    hi = jnp.einsum("bhmn,bhnde->bhmde", a, hb)
    zi = jnp.einsum("bhmn,bhnd->bhmd", a, zb)
    tm = plan.num_q_blocks
    qpb = qp.astype(jnp.float32).reshape(b, h, tm, cfg.block_q, d)
    num = jnp.einsum("bhmqd,bhmde->bhmqe", qpb, hi)
    den = jnp.einsum("bhmqd,bhmd->bhmq", qpb, zi)[..., None]
    o_l = _safe_div(num, den).reshape(b, h, n, d)
    return o_s, o_l
