"""SLA core: the paper's primary contribution (sparse-linear attention).

Organized as a plan/execute split (DESIGN.md):
  masks.py    — P_c prediction + three-way block classification (Eq. 2-3)
  plan.py     — SLAPlan pytree: LUTs + aggregation structure, built once
  backends.py — execution backend registry (reference / gather / kernel)
  sla.py      — the public `sla_attention` wrapper
"""
from repro.core.backends import (
    available_backends,
    decode_execute,
    execute,
    get_backend,
    register_backend,
    register_decode_backend,
    resolve,
    resolve_decode,
)
from repro.core.config import SLAConfig
from repro.core.masks import (
    check_routing_mode,
    classify_blocks,
    classify_row,
    compute_mask,
    expand_mask,
    pool_blocks,
    predict_pc,
    predict_pc_row,
    predict_routing,
    predict_routing_row,
    routing_gates,
    routing_init,
    row_valid,
    score_map,
    score_row,
    sparsity_stats,
)
from repro.core.phi import PHI_KINDS, phi
from repro.core.plan import (
    SLAPlan,
    build_col_lut,
    build_lut,
    empty_plan,
    plan_attention,
    plan_drift,
    plan_extend,
    plan_from_mask,
    plan_retention,
    refresh_plan,
)
from repro.core.sla import sla_attention, sla_init
from repro.core import reference, flops

__all__ = [
    "SLAConfig", "phi", "PHI_KINDS",
    "pool_blocks", "predict_pc", "classify_blocks", "compute_mask",
    "expand_mask", "sparsity_stats",
    "predict_pc_row", "classify_row", "row_valid",
    "predict_routing", "predict_routing_row", "routing_gates",
    "routing_init", "check_routing_mode", "score_map", "score_row",
    "SLAPlan", "plan_attention", "plan_from_mask",
    "plan_drift", "plan_retention", "refresh_plan",
    "empty_plan", "plan_extend",
    "build_lut", "build_col_lut",
    "execute", "get_backend", "register_backend", "available_backends",
    "resolve",
    "decode_execute", "register_decode_backend", "resolve_decode",
    "sla_attention", "sla_init", "reference", "flops",
]
