"""SLA core: the paper's primary contribution (sparse-linear attention)."""
from repro.core.config import SLAConfig
from repro.core.masks import (
    build_lut,
    classify_blocks,
    compute_mask,
    expand_mask,
    pool_blocks,
    predict_pc,
    sparsity_stats,
)
from repro.core.phi import PHI_KINDS, phi
from repro.core.sla import sla_attention, sla_init
from repro.core import reference, flops

__all__ = [
    "SLAConfig", "phi", "PHI_KINDS",
    "pool_blocks", "predict_pc", "classify_blocks", "compute_mask",
    "build_lut", "expand_mask", "sparsity_stats",
    "sla_attention", "sla_init", "reference", "flops",
]
