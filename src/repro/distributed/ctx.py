"""Activation-sharding context (SP) + remat policy plumbing.

Models call `shard_residual(x)` between blocks; under an active context
this applies with_sharding_constraint (sequence-parallel residual stream:
d_model over "model", batch over dp — the Megatron-SP layout GSPMD turns
into all-gather/reduce-scatter pairs at the TP boundary). Outside a mesh
context it is a no-op, so tests and small examples run unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ActivationSharding:
    mesh: Any
    residual: P  # (B, S, D) residual stream
    remat: bool = True


_CTX: contextvars.ContextVar[Optional[ActivationSharding]] = \
    contextvars.ContextVar("activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, residual: P, remat: bool = True):
    token = _CTX.set(ActivationSharding(mesh, residual, remat))
    try:
        yield
    finally:
        _CTX.reset(token)


def default_residual_spec(mesh, global_batch: int, seq_len: int) -> P:
    from repro.distributed.sharding import pick_dp_axes
    dp = pick_dp_axes(mesh, global_batch)
    if dp:
        return P(dp, None, "model")
    if seq_len % dict(mesh.shape).get("data", 1) == 0:
        return P(None, "data", "model")  # context parallelism
    return P()


def shard_residual(x: jax.Array) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None or x.ndim != 3:
        return x
    mesh_shape = dict(ctx.mesh.shape)
    fixed = []
    for dim, names in zip(x.shape, tuple(ctx.residual) + (None,) * 3):
        if names is None:
            fixed.append(None)
            continue
        ax = names if isinstance(names, tuple) else (names,)
        size = 1
        for a in ax:
            size *= mesh_shape.get(a, 1)
        fixed.append(names if size > 1 and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def fsdp_gather(w: jax.Array, kind: str) -> jax.Array:
    """Per-layer FSDP weight gather (MaxText-style): parameters are STORED
    sharded over ("data" x "model"); before use, constrain the compute
    copy to TP-only sharding — GSPMD emits one small all-gather per layer
    and the activation matmuls get unambiguous output shardings (without
    this, the FSDP-sharded output dim forces all-reduce + reshard).

    kind: "col" (in, out_tp) -> P(None, "model");
          "row" (in_tp, out) -> P("model", None).
    """
    ctx = _CTX.get()
    if ctx is None or w.ndim != 2:
        return w
    mesh_shape = dict(ctx.mesh.shape)
    msz = mesh_shape.get("model", 1)
    if msz <= 1:
        return w
    if kind == "col" and w.shape[1] % msz == 0:
        return jax.lax.with_sharding_constraint(w, P(None, "model"))
    if kind == "row" and w.shape[0] % msz == 0:
        return jax.lax.with_sharding_constraint(w, P("model", None))
    return w


def ep_gather(w: jax.Array) -> jax.Array:
    """MoE expert weights (E, d_in, d_out): stored FSDP-sharded on d_in;
    gather to experts-only sharding before the expert matmul (otherwise
    the (E_loc, capacity, d_ff) expert GEMM contracts the FSDP dim and
    all-reduces a multi-GB activation per layer — measured on moonshot)."""
    ctx = _CTX.get()
    if ctx is None or w.ndim != 3:
        return w
    msz = dict(ctx.mesh.shape).get("model", 1)
    if msz > 1 and w.shape[0] % msz == 0:
        return jax.lax.with_sharding_constraint(w, P("model", None, None))
    return w


def shard_expert_buf(x: jax.Array) -> jax.Array:
    """Constrain the (E, capacity, d) dispatch buffer to expert sharding
    so the scatter-add resolves into expert-shard transfers instead of a
    full all-reduce of the whole buffer."""
    ctx = _CTX.get()
    if ctx is None or x.ndim != 3:
        return x
    msz = dict(ctx.mesh.shape).get("model", 1)
    if msz > 1 and x.shape[0] % msz == 0:
        return jax.lax.with_sharding_constraint(x, P("model", None, None))
    return x


def use_remat() -> bool:
    ctx = _CTX.get()
    return ctx.remat if ctx is not None else False


def maybe_remat(fn):
    """Wrap a scan body with full rematerialization when the context asks
    for it (the memory policy that makes 88-layer x 32K cells fit HBM)."""
    if use_remat():
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn
