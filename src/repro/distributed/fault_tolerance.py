"""Fault tolerance: straggler watchdog, retryable step execution, and the
restart contract.

At 1000+ nodes the failure model is: (a) hard node loss -> the JAX
runtime surfaces a distributed error, the job restarts from the latest
atomic checkpoint (checkpoint/manager.py) with `latest_step()` resume;
(b) stragglers -> per-step wall times are tracked with an EMA; steps
slower than `threshold x EMA` are flagged with the host id so the
scheduler can drain/hot-swap the slow host; (c) data corruption ->
loss/grad-norm NaN guards skip the update and count strikes.

`FaultPlan`/`FaultEvent` are the deterministic injection side of the
same contract: the disaggregated serving harness (serving/disagg.py)
consumes a scripted schedule of kill/straggle/flake events so that
worker loss, drain, and requeue are exercised reproducibly in tests
instead of waiting for real hardware to fail.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

FAULT_KINDS = ("kill", "straggle", "flake")
FAULT_POOLS = ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: at scheduler tick `tick`, do `kind` to
    worker `worker` of pool `pool`.

    kind == "kill":     the worker dies; its in-flight work must be
                        requeued (or the loss surfaced loudly).
    kind == "straggle": the worker's measured tick durations are
                        multiplied by `factor` from then on, so the
                        StragglerWatchdog sees a genuinely slow host.
    kind == "flake":    the worker's next `failures` ticks raise a
                        transient RuntimeError (absorbed by
                        run_with_retries).
    """
    tick: int
    kind: str
    pool: str
    worker: int
    factor: float = 1.0
    failures: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.pool not in FAULT_POOLS:
            raise ValueError(f"unknown worker pool {self.pool!r}; "
                             f"expected one of {FAULT_POOLS}")
        if self.tick < 0:
            raise ValueError("fault tick must be >= 0")


class FaultPlan:
    """A deterministic schedule of FaultEvents, popped by tick.

    `due(tick)` returns (and consumes) every event whose tick has
    arrived, in (tick, pool, worker) order so multi-fault ticks replay
    identically run over run.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self._pending: List[FaultEvent] = sorted(
            events, key=lambda e: (e.tick, e.pool, e.worker, e.kind))
        self.fired: List[FaultEvent] = []

    def due(self, tick: int) -> List[FaultEvent]:
        ready = [e for e in self._pending if e.tick <= tick]
        if ready:
            self._pending = [e for e in self._pending if e.tick > tick]
            self.fired.extend(ready)
        return ready

    @property
    def exhausted(self) -> bool:
        return not self._pending

    @property
    def pending(self) -> Tuple[FaultEvent, ...]:
        return tuple(self._pending)


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA-based step-time anomaly detector."""
    threshold: float = 2.0
    decay: float = 0.9
    warmup: int = 5
    ema: float = 0.0
    steps: int = 0
    flagged: List[dict] = dataclasses.field(default_factory=list)

    def record(self, seconds: float, host_id: int = 0) -> bool:
        """Returns True if this step is a straggler."""
        self.steps += 1
        if self.steps <= self.warmup:
            self.ema = seconds if self.ema == 0 else \
                self.decay * self.ema + (1 - self.decay) * seconds
            return False
        slow = seconds > self.threshold * self.ema
        if slow:
            self.flagged.append({"step": self.steps, "host": host_id,
                                 "seconds": seconds, "ema": self.ema})
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * seconds
        return slow


@dataclasses.dataclass
class NaNGuard:
    """Skips poisoned updates; aborts after `max_strikes` consecutive."""
    max_strikes: int = 3
    strikes: int = 0

    def check(self, loss) -> bool:
        """True -> step is healthy; False -> skip this update."""
        healthy = bool(jnp.isfinite(loss))
        if healthy:
            self.strikes = 0
        else:
            self.strikes += 1
            if self.strikes >= self.max_strikes:
                raise FloatingPointError(
                    f"{self.strikes} consecutive non-finite losses — "
                    "aborting for restart from checkpoint")
        return healthy


def run_with_retries(step_fn: Callable, max_retries: int = 2,
                     on_retry: Optional[Callable] = None,
                     sleep: Callable[[float], None] = time.sleep):
    """Execute one step, retrying on transient runtime errors (the
    single-process analogue of restart-on-collective-timeout).

    `sleep` is injectable so fault-injection tests can record the
    backoff schedule instead of actually waiting for it."""
    for attempt in range(max_retries + 1):
        try:
            return step_fn()
        except (jax.errors.JaxRuntimeError, RuntimeError) as e:
            if attempt == max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(min(2.0 ** attempt, 10.0))
