"""Fault tolerance: straggler watchdog, retryable step execution, and the
restart contract.

At 1000+ nodes the failure model is: (a) hard node loss -> the JAX
runtime surfaces a distributed error, the job restarts from the latest
atomic checkpoint (checkpoint/manager.py) with `latest_step()` resume;
(b) stragglers -> per-step wall times are tracked with an EMA; steps
slower than `threshold x EMA` are flagged with the host id so the
scheduler can drain/hot-swap the slow host; (c) data corruption ->
loss/grad-norm NaN guards skip the update and count strikes.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class StragglerWatchdog:
    """EMA-based step-time anomaly detector."""
    threshold: float = 2.0
    decay: float = 0.9
    warmup: int = 5
    ema: float = 0.0
    steps: int = 0
    flagged: List[dict] = dataclasses.field(default_factory=list)

    def record(self, seconds: float, host_id: int = 0) -> bool:
        """Returns True if this step is a straggler."""
        self.steps += 1
        if self.steps <= self.warmup:
            self.ema = seconds if self.ema == 0 else \
                self.decay * self.ema + (1 - self.decay) * seconds
            return False
        slow = seconds > self.threshold * self.ema
        if slow:
            self.flagged.append({"step": self.steps, "host": host_id,
                                 "seconds": seconds, "ema": self.ema})
        else:
            self.ema = self.decay * self.ema + (1 - self.decay) * seconds
        return slow


@dataclasses.dataclass
class NaNGuard:
    """Skips poisoned updates; aborts after `max_strikes` consecutive."""
    max_strikes: int = 3
    strikes: int = 0

    def check(self, loss) -> bool:
        """True -> step is healthy; False -> skip this update."""
        healthy = bool(jnp.isfinite(loss))
        if healthy:
            self.strikes = 0
        else:
            self.strikes += 1
            if self.strikes >= self.max_strikes:
                raise FloatingPointError(
                    f"{self.strikes} consecutive non-finite losses — "
                    "aborting for restart from checkpoint")
        return healthy


def run_with_retries(step_fn: Callable, max_retries: int = 2,
                     on_retry: Optional[Callable] = None):
    """Execute one step, retrying on transient runtime errors (the
    single-process analogue of restart-on-collective-timeout)."""
    for attempt in range(max_retries + 1):
        try:
            return step_fn()
        except (jax.errors.JaxRuntimeError, RuntimeError) as e:
            if attempt == max_retries:
                raise
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(min(2.0 ** attempt, 10.0))
