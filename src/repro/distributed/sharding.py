"""Sharding rules: parameter, input, and cache PartitionSpecs.

Strategy (DESIGN.md §5):
  * TP over "model": column-parallel in-projections, row-parallel
    out-projections (Megatron pairing), experts (EP), vocab.
  * FSDP/ZeRO over "data": every weight's *other* large dim shards over
    data; optimizer state follows automatically (params-shaped pytree).
  * DP over ("pod", "data") for batches; when global_batch < |dp axes|
    (long_500k: batch 1) the *sequence* axis shards over "data" instead
    (context parallelism).

Rules are keyed by leaf name; specs describe the TRAILING dims and are
left-padded with None for stacked-layer leading dims.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# leaf name -> spec of trailing dims
_PARAM_RULES: Dict[str, Tuple] = {
    # embeddings: vocab over model (TP), d over data (FSDP)
    "embed": ("model", "data"),
    "unembed": ("model", "data"),
    # column-parallel (d_in, d_out_tp)
    "wq": ("data", "model"), "wk": ("data", "model"),
    "wv": ("data", "model"), "wg": ("data", "model"),
    "wr": ("data", "model"), "mlp_wi": ("data", "model"),
    "ck": ("data", "model"), "cr": ("data", "model"),
    "in_proj": ("data", "model"), "xq": ("data", "model"),
    "xk": ("data", "model"), "xv": ("data", "model"),
    "ada": ("data", "model"), "shared_wi": ("data", "model"),
    # row-parallel (d_in_tp, d_out)
    "wo": ("model", "data"), "mlp_wo": ("model", "data"),
    "cv": ("model", "data"), "out_proj": ("model", "data"),
    "xo": ("model", "data"), "shared_wo": ("model", "data"),
    # MoE: experts over model (EP), d over data
    "wi": ("model", "data", None),
    "router": ("data", None),
    # SLA proj / rwkv bonus: heads over model
    "sla_proj": ("model", None, None),
    "u": ("model", None),
    # misc projections
    "patch_in": ("data", None),
    "patch_out": ("data", None),
    "t_embed": (None, "data"),
    "wa": ("data", None),
    "wb": (None, "data"),
    "conv": (None, "model"),
}
# moe wo is (E, ff, d): experts over model
_PARAM_RULES_3D = {
    "wo": ("model", None, "data"),
    "wi": ("model", "data", None),
}


def param_spec(path: str, ndim: int) -> P:
    name = path.split("/")[-1]
    in_moe = "/moe/" in path or path.endswith("moe")
    rules = None
    if in_moe and name in _PARAM_RULES_3D:
        rules = _PARAM_RULES_3D[name]
    elif name in _PARAM_RULES:
        rules = _PARAM_RULES[name]
    if rules is None:
        return P()  # replicate (norm scales etc.)
    if ndim < len(rules):
        # e.g. unstacked variant — drop leading rule dims
        rules = rules[len(rules) - ndim:]
    pad = (None,) * (ndim - len(rules))
    return P(*(pad + tuple(rules)))


def _path_str(path) -> str:
    parts = []
    for pe in path:
        if hasattr(pe, "key"):
            parts.append(str(pe.key))
        elif hasattr(pe, "idx"):
            parts.append(str(pe.idx))
    return "/".join(parts)


def _divisible(spec: P, shape, mesh) -> P:
    """Drop sharding on dims the mesh doesn't divide (e.g. tiny LoRA dims)."""
    fixed = []
    for dim, names in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                          - len(spec))):
        if names is None:
            fixed.append(None)
            continue
        ax_names = names if isinstance(names, tuple) else (names,)
        size = 1
        for a in ax_names:
            size *= mesh.shape[a]
        fixed.append(names if dim % size == 0 and dim >= size else None)
    return P(*fixed)


def param_shardings(mesh, params_shape) -> Any:
    """Pytree of NamedShardings matching a (possibly abstract) params tree."""
    def one(path, leaf):
        spec = param_spec(_path_str(path), len(leaf.shape))
        spec = _divisible(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def dp_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def pick_dp_axes(mesh, global_batch: int) -> Tuple[str, ...]:
    """Largest dp-axis subset the batch divides: full ("pod","data"),
    then ("data",), then ("pod",). Falling back to a subset keeps
    attention shard-local (the remaining axis becomes pure DP via the
    gradient all-reduce) instead of forcing sequence shards — measured
    40x collective reduction on wan2.1 x multi-pod (§Perf)."""
    for cand in (dp_axes(mesh), ("data",), ("pod",)):
        cand = tuple(a for a in cand if a in mesh.shape)
        if not cand:
            continue
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        if global_batch >= size and global_batch % size == 0:
            return cand
    return ()


def batch_shardings(mesh, batch_specs, global_batch: int) -> Any:
    """Input shardings: batch over the largest dividing dp-axis subset,
    or sequence over 'data' when none fits (context parallelism for
    long_500k)."""
    dp = pick_dp_axes(mesh, global_batch)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    shard_seq = not dp

    def one(leaf):
        if leaf is None:
            return None
        shape = leaf.shape
        if len(shape) == 0:
            return NamedSharding(mesh, P())
        if shard_seq:
            if len(shape) >= 2 and shape[1] % mesh.shape["data"] == 0:
                spec = P(None, "data")
            else:
                spec = P()
        else:
            spec = P(dp) if shape[0] % dp_size == 0 else P()
        return NamedSharding(mesh, _divisible(spec, shape, mesh))
    return jax.tree.map(one, batch_specs, is_leaf=lambda x: x is None)


def _dp_size(mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def cache_shardings(mesh, cache_specs, global_batch: int) -> Any:
    """KV/state cache shardings. Layout (L, B, H, S, D) or (L, B, H, Dk, Dv).

    decode_32k (B=128): batch over dp, heads over model.
    long_500k (B=1):   sequence over data (context-parallel cache),
                       heads over model.
    """
    dp = pick_dp_axes(mesh, global_batch)
    dp_size = _dp_size(mesh, dp)
    shard_seq = not dp

    def one(path, leaf):
        shape = leaf.shape
        if len(shape) <= 1:
            return NamedSharding(mesh, P())
        name = _path_str(path)
        if len(shape) == 5:  # (L, B, H, S, D) kv cache / (L,B,H,dk,dv) state
            is_state = "state" in name or "ssm" in name
            model_sz = mesh.shape.get("model", 1)
            heads_ok = shape[2] % model_sz == 0 and shape[2] >= model_sz
            if shard_seq and not is_state:
                spec = (P(None, None, "model", "data", None) if heads_ok
                        else P(None, None, None, ("data", "model"), None))
            elif is_state or heads_ok:
                spec = P(None, dp, "model", None, None)
            else:
                # few KV heads (GQA): shard the sequence dim over "model"
                # instead (flash-decoding layout — partial softmax + combine)
                spec = P(None, dp, None, "model", None)
        elif len(shape) == 4:  # (L, B, S, D) conv tails etc.
            spec = P(None, None if shard_seq else dp, None, None)
        elif len(shape) == 2:
            spec = P(None if shard_seq else dp)
        else:
            spec = P()
        return NamedSharding(mesh, _divisible(spec, shape, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_specs)
