"""Elastic scaling: reshard live training state onto a new mesh.

At 1000+ nodes, node loss shrinks the healthy device set; rather than
waiting for replacements, the job can *remesh*: pick the largest
(data', model') grid that fits the survivors, reshard params/opt state,
and continue (batch per data-group grows transparently because the data
pipeline is a pure function of global_step).

Two entry points:
  * `remesh(state, old_specs_fn, new_mesh)` — in-memory reshard via
    device_put (works because our checkpoints/state are logically
    unsharded pytrees; GSPMD handles the device movement).
  * checkpoint-based: CheckpointManager.restore(..., shardings=new) —
    exercised cross-device-count in tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax

from repro.distributed.sharding import param_shardings


def best_mesh_shape(num_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid on the surviving devices, preserving the
    model-parallel degree (params are sharded over it; changing it needs
    a reshard anyway, which we do — but keeping it avoids repadding)."""
    model = model_parallel
    while model > 1 and num_devices % model:
        model //= 2
    data = num_devices // model
    return data, model


def remesh(params: Any, opt_state: Any, new_mesh) -> Tuple[Any, Any]:
    """Reshard live state onto `new_mesh` (survivor set after node loss)."""
    p_spec = param_shardings(new_mesh, jax.eval_shape(lambda: params))
    from jax.sharding import NamedSharding, PartitionSpec as P
    o_spec = {"m": p_spec, "v": p_spec,
              "step": NamedSharding(new_mesh, P())}
    return (jax.device_put(params, p_spec),
            jax.device_put(opt_state, o_spec))
