"""Error-feedback gradient compression for the slow cross-pod hop.

int8 block-quantization with error feedback (EF-SGD style): before the
pod-axis all-reduce, quantize g + e to int8 with a per-block f32 scale
(32.25x smaller than f32, 8.06x smaller than bf16 wire format including
scales at block=128); the residual e' = (g + e) - deq(q) is carried to
the next step, so compression error accumulates in the optimizer path
instead of being lost — the property that keeps convergence intact.

Convergence is validated in tests/test_optim.py (quadratic + small-LM
fits); the dry-run's multi-pod cells show the pod-axis all-reduce bytes
this removes (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


def _pad_to(x: jax.Array, m: int) -> jax.Array:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % m
    return jnp.pad(flat, (0, pad))


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g (any shape, f32/bf16) -> (int8 codes (Nb, BLOCK), f32 scales (Nb,))."""
    flat = _pad_to(g.astype(jnp.float32), BLOCK).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(flat / safe[:, None]), -127, 127) \
        .astype(jnp.int8)
    return codes, scale


def dequantize(codes: jax.Array, scale: jax.Array, shape,
               dtype=jnp.float32) -> jax.Array:
    flat = codes.astype(jnp.float32) * scale[:, None]
    n = 1
    for d in shape:
        n *= d
    return flat.reshape(-1)[:n].reshape(shape).astype(dtype)


def ef_init(grads) -> dict:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_compress_decompress(grads, error) -> Tuple[dict, dict, dict]:
    """Simulate the compressed wire format locally (the all-reduce then
    runs on the dequantized tensor; on hardware the int8 codes are what
    crosses the pod link). Returns (grads_hat, new_error, stats)."""
    bits_full = 0
    bits_wire = 0

    def one(g, e):
        nonlocal bits_full, bits_wire
        x = g.astype(jnp.float32) + e
        codes, scale = quantize(x)
        xhat = dequantize(codes, scale, g.shape)
        bits_full += g.size * 32
        bits_wire += codes.size * 8 + scale.size * 32
        return xhat, x - xhat

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    ghat = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return ghat, new_e, {"compression_x": bits_full / max(bits_wire, 1)}
