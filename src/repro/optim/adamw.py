"""Pure-JAX AdamW with global-norm clipping and schedules.

Optimizer state is a params-shaped pytree, so GSPMD shards m/v exactly
like the parameters (ZeRO-style: FSDP-sharded params => FSDP-sharded
optimizer state, updates are fully local).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def update(params, grads, state, cfg: AdamWConfig
           ) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_p = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_p).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
