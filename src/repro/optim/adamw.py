"""Pure-JAX AdamW with global-norm clipping and schedules.

Optimizer state is a params-shaped pytree, so GSPMD shards m/v exactly
like the parameters (ZeRO-style: FSDP-sharded params => FSDP-sharded
optimizer state, updates are fully local).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def trainable_mask(params, substrings) -> dict:
    """Params-shaped pytree of python bools: True where the leaf path
    contains any of `substrings` (e.g. ("routing", "sla_proj") for the
    fixed-FLOP fine-tuning recipe that trains only the SLA merge and the
    learned routing head). Feed to `update(..., trainable=)`."""
    subs = tuple(substrings)

    def mark(path, _leaf):
        name = jax.tree_util.keystr(path)
        return any(s in name for s in subs)

    return jax.tree_util.tree_map_with_path(mark, params)


def update(params, grads, state, cfg: AdamWConfig, trainable=None
           ) -> Tuple[dict, dict, dict]:
    """Returns (new_params, new_state, metrics).

    `trainable`: optional params-shaped pytree of (python) bools — see
    `trainable_mask`. Frozen leaves keep their params AND moments
    untouched (the frozen subtree is dropped from the compiled update
    entirely, it is not a runtime select), so a later full fine-tune
    resumes from clean moment state. Gradient clipping (and the
    reported grad_norm) covers ONLY the trainable leaves — the
    effective step size of a selective fine-tune must not depend on
    gradient mass flowing into parameters that are never updated."""
    step = state["step"] + 1
    if trainable is None:
        gnorm = global_norm(grads)
    else:
        gnorm = global_norm([
            g for g, t in zip(jax.tree_util.tree_leaves(grads),
                              jax.tree_util.tree_leaves(trainable)) if t])
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, t):
        if not t:  # frozen (static python bool): no update, no moments
            return p, m, v
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_p = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_p).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    flat_t = ([True] * len(flat_p) if trainable is None
              else [bool(t) for t in jax.tree_util.tree_leaves(trainable)])
    out = [upd(p, g, m, v, t) for p, g, m, v, t
           in zip(flat_p, flat_g, flat_m, flat_v, flat_t)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
