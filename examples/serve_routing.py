"""Worked example for every launch/serve.py flag, centered on routing.

Runs the SAME synthetic request stream through four serving
configurations and prints a comparison table:

  1. baseline          dense decode, plan per chunk, threshold routing
  2. +plan-reuse       `--plan-reuse adaptive --drift-threshold 0.1`
                       (prefill block plans reused across request
                       chunks, re-planned on measured drift)
  3. +decode-sla       `--decode-sla` (incremental decode plans + the
                       O(1) linear running state; per-token attention
                       is critical-blocks + O(1), not O(context))
  4. +learned routing  `--routing-mode learned` on top of (3): the
                       trainable SLA2-style block scorer. At identity
                       init it reproduces the threshold router
                       BITWISE, so this run must emit the same tokens
                       as (3) — asserted below. After a distillation
                       fine-tune (launch/train.py --distill
                       --routing-mode learned --train-only
                       routing,sla_proj) the scorer routes better than
                       the hand-tuned rule at the same FLOP budget.

Every configuration is driven through `repro.launch.serve.main`, i.e.
the real CLI surface:

    PYTHONPATH=src python examples/serve_routing.py
"""
import contextlib
import io

from repro.launch import serve

COMMON = ["--arch", "qwen3-1.7b", "--smoke", "--requests", "4",
          "--batch", "2", "--prompt-len", "32", "--max-new", "8",
          "--backend", "gather"]

CONFIGS = [
    ("baseline", []),
    ("plan-reuse", ["--plan-reuse", "adaptive",
                    "--drift-threshold", "0.1"]),
    ("decode-sla", ["--decode-sla"]),
    ("decode-sla+learned", ["--decode-sla",
                            "--routing-mode", "learned"]),
]


def main():
    tokens = {}
    for name, extra in CONFIGS:
        argv = COMMON + extra
        print(f"\n=== {name}: serve.py {' '.join(extra) or '(defaults)'} "
              f"===")
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            done = serve.main(argv)
        print(buf.getvalue().strip())
        tokens[name] = [r.tokens_out for r in done]
        assert all(len(r.tokens_out) == r.max_new_tokens for r in done)

    # identity-initialized learned routing must route exactly like the
    # threshold rule — same plans, same tokens (DESIGN.md "Learned
    # routing"); fresh params make the two decode-SLA runs comparable
    assert tokens["decode-sla+learned"] == tokens["decode-sla"], \
        "learned routing at init must reproduce threshold routing"
    print("\nlearned routing at identity init emitted identical tokens "
          "to threshold routing (bitwise plan parity) — fine-tune with "
          "launch/train.py --distill --routing-mode learned to move it")


if __name__ == "__main__":
    main()
