"""Serving API v2 end to end: continuous batching, streaming, sampling.

    PYTHONPATH=src python examples/serve_stream.py

Drives `repro.serving.api.Scheduler` directly (the surface
`launch/serve.py --scheduler continuous` wraps): staggered submissions,
per-token StreamEvents, mixed greedy/temperature sampling with stop
tokens, and the per-request metrics the v1 engine could not report —
then cross-checks greedy tokens against the static-batch engine.
"""
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serving.api import SamplingParams, Scheduler
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_arch("qwen3-1.7b").smoke()  # CPU-runnable reduction
    mdl = registry.get_model(cfg)
    params = mdl.init(jax.random.PRNGKey(0), cfg)
    rs = np.random.default_rng(0)
    prompts = [rs.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (32, 20, 32, 24)]
    budgets = [6, 14, 4, 9]

    sched = Scheduler(cfg, params, num_slots=2, max_len=96,
                      prefill_bucket=32)
    t0 = time.time()
    # two requests up front...
    for p, b in zip(prompts[:2], budgets[:2]):
        sched.submit(p, SamplingParams(max_new_tokens=b))
    # ...stream a few steps, then two more arrive mid-flight (the
    # staggered-arrival pattern static batching cannot express)
    n_events = 0
    for _ in range(3):
        for ev in sched.step():
            n_events += 1
    sched.submit(prompts[2], SamplingParams(max_new_tokens=budgets[2]))
    sched.submit(prompts[3], SamplingParams(max_new_tokens=budgets[3],
                                            temperature=0.8, seed=7))
    for ev in sched.stream():
        n_events += 1
        if ev.kind == "token":
            print(f"  [{ev.t - t0:6.3f}s] req {ev.rid} "
                  f"token[{ev.index}] = {ev.token}")
        else:
            print(f"  [{ev.t - t0:6.3f}s] req {ev.rid} -- {ev.kind}")
    done = sched.drain()

    print(f"\n{len(done)} requests, {n_events} events, "
          f"occupancy {sched.stats.occupancy():.2f}, "
          f"{sched.stats.admissions} admissions")
    for r in done:
        m = r.metrics
        print(f"  req {r.rid}: {len(r.tokens_out)} tok | queue "
              f"{m.queue_s*1e3:.0f}ms | ttft {m.ttft_s*1e3:.0f}ms | "
              f"latency {m.latency_s*1e3:.0f}ms")

    # greedy requests must match the static-batch engine exactly (same
    # decode batch width: a full group of 2 vs the 2-slot pool)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budgets[i])
            for i in range(2)]
    static = ServingEngine(cfg, params, batch_size=2, max_len=96)
    for a, b in zip(static.run(reqs), done[:2]):
        assert a.tokens_out == b.tokens_out, (a.rid, a.tokens_out,
                                              b.tokens_out)
    print("greedy tokens identical to the static-batch engine")


if __name__ == "__main__":
    main()
