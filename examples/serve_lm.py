"""Serve a small LM with batched requests: SLA prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --batch 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import registry
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke()  # CPU-runnable reduced config
    mdl = registry.get_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = mdl.init(rng, cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"serving {cfg.name} (reduced, {n/1e6:.2f}M params), "
          f"batch={args.batch}")

    rs = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i,
                prompt=rs.integers(0, cfg.vocab_size,
                                   size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new - (i % 3))
        for i in range(args.requests)
    ]
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_len=args.prompt_len + args.max_new + 8)
    t0 = time.time()
    done = engine.run(reqs)
    wall = time.time() - t0
    st = engine.stats
    print(f"served {len(done)} requests in {wall:.1f}s "
          f"(incl. compile)")
    print(f"prefill: {st.prefill_tokens} tok in {st.prefill_s:.2f}s | "
          f"decode: {st.decode_tokens} tok in {st.decode_s:.2f}s | "
          f"decode-slot occupancy {st.occupancy():.2f}")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.tokens_out)} tokens | ttft "
              f"{r.metrics.ttft_s*1e3:.0f}ms | latency "
              f"{r.latency_s*1e3:.0f}ms -> {r.tokens_out[:8]}...")
    assert all(len(r.tokens_out) == r.max_new_tokens for r in done)
    assert all(r.latency_s == r.metrics.latency_s for r in done)
    print("all requests honored their token budgets; see "
          "examples/serve_stream.py for the v2 continuous scheduler")


if __name__ == "__main__":
    main()
