"""Quickstart: SLA attention in 60 seconds.

Shows the three-way block classification, the FLOPs reduction at the
paper's operating point, agreement between the three execution paths
(dense reference / XLA gather / fused Pallas kernel), and gradients.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (SLAConfig, compute_mask, plan_attention, resolve,
                        sla_attention, sla_init, sparsity_stats, flops)
from repro.core.phi import phi
from repro.kernels.ops import sla_attention_core
from repro.kernels.ref import sla_attention_core_reference


def main(backend: str = "gather"):
    backend = resolve(backend)  # unknown backend= fails loudly, up front
    rng = jax.random.PRNGKey(0)
    B, H, N, D = 1, 4, 1024, 64
    cfg = SLAConfig(block_q=64, block_kv=64, kh_frac=0.05, kl_frac=0.10,
                    phi="softmax", causal=False)
    rq, rk, rv = jax.random.split(rng, 3)
    q = jax.random.normal(rq, (B, H, N, D), jnp.float32)
    k = jax.random.normal(rk, (B, H, N, D), jnp.float32)
    v = jax.random.normal(rv, (B, H, N, D), jnp.float32)

    # 1. classification (Eq. 2-3)
    mc = compute_mask(q, k, cfg)
    stats = sparsity_stats(mc)
    print("block classification:",
          {kk: round(float(vv), 4) for kk, vv in stats.items()})

    # 2. FLOPs accounting at the paper's operating point (Table 1)
    acct = flops.sla_flops(32768, 128, 12, cfg)
    print(f"attention FLOPs at Wan2.1 shape: full={acct['full']:.3e} "
          f"sla={acct['total']:.3e} reduction={acct['reduction_x']:.1f}x")

    # 3. plan once, then all three execution backends agree on it
    params = sla_init(rng, H, D, cfg)
    plan = plan_attention(q, k, cfg)
    out_ref = sla_attention(params, q, k, v, cfg, backend="reference",
                            plan=plan)
    out_gather = sla_attention(params, q, k, v, cfg, backend="gather",
                               plan=plan)
    out_kernel = sla_attention(params, q, k, v, cfg, backend="kernel",
                               plan=plan)
    print("gather vs reference max|err|:",
          float(jnp.abs(out_gather - out_ref).max()))
    print("pallas vs reference max|err|:",
          float(jnp.abs(out_kernel - out_ref).max()))

    # 4. everything is differentiable (the paper's fine-tuning mode)
    def loss(p, q):
        return jnp.sum(sla_attention(p, q, k, v, cfg,
                                     backend=backend) ** 2)

    gp, gq = jax.grad(loss, argnums=(0, 1))(params, q)
    print("grad norms: proj", float(jnp.linalg.norm(gp["proj"])),
          "dq", float(jnp.linalg.norm(gq)))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="gather",
                    help="SLA execution backend (core.backends registry)")
    main(backend=ap.parse_args().backend)
