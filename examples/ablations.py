"""Ablations on a live toy model (paper Table 2 structure, mechanism-level):
phi activation sweep and k_h sweep, measured as attention-output fidelity
against full attention on a *trained* DiT's real Q/K/V (random weights
give unstructured attention; trained maps are what the paper classifies).

    PYTHONPATH=src python examples/ablations.py
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import SLAConfig, compute_mask, sla_attention, sla_init
from repro.core.flops import sla_flops
from repro.data.pipeline import DataConfig, latent_batch
from repro.configs.base import ShapeConfig
from repro.models import dit
from examples.finetune_dit import build, train


def attention_fidelity(q, k, v, cfg, rng):
    """Relative L2 error of SLA output vs full attention (proxy metric;
    proj is identity-initialized here so the linear branch contributes)."""
    params = sla_init(rng, q.shape[1], q.shape[-1],
                      dataclasses.replace(cfg, proj_init="identity"))
    full = sla_attention(None, q, k, v, cfg.replace(mode="full"))
    out = sla_attention(params, q, k, v, cfg)
    return float(jnp.linalg.norm(out - full) / jnp.linalg.norm(full))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()
    rng = jax.random.PRNGKey(0)

    # quickly train a small DiT so Q/K have realistic structure
    cfg_model = build("small", "full")
    cfg_model = dataclasses.replace(cfg_model, num_layers=4)
    shape = ShapeConfig("dit", args.seq, 8, "train")
    params = dit.init(rng, cfg_model)
    params, _ = train(cfg_model, params, shape, args.train_steps, 3e-4, 0,
                      log_every=1000)

    # pull real q, k, v from layer 0 on a fresh batch
    batch = {k: jnp.asarray(v) for k, v in latent_batch(
        cfg_model, shape, DataConfig(seed=7), 0).items()}
    x = jnp.einsum("bnp,pd->bnd", batch["latents"],
                   params["patch_in"])
    p0 = jax.tree.map(lambda t: t[0], params["layers"])
    b, n, d = x.shape
    h, dh = cfg_model.num_heads, cfg_model.head_dim
    q = jnp.einsum("bsd,de->bse", x, p0["wq"]).reshape(b, n, h, dh) \
        .transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,de->bse", x, p0["wk"]).reshape(b, n, h, dh) \
        .transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,de->bse", x, p0["wv"]).reshape(b, n, h, dh) \
        .transpose(0, 2, 1, 3)

    base = SLAConfig(block_q=32, block_kv=32, kh_frac=0.10, kl_frac=0.20)

    print("\n--- phi ablation (paper Table 2, activation rows) ---")
    for phi in ("softmax", "elu1", "relu"):
        cfg = base.replace(phi=phi)
        err = attention_fidelity(q, k, v, cfg, rng)
        print(f"  phi={phi:8s} rel-L2 error vs full: {err:.4f}")

    print("\n--- k_h ablation (paper Table 2, Top-k rows) ---")
    for kh in (0.05, 0.10, 0.20):
        cfg = base.replace(kh_frac=kh)
        err = attention_fidelity(q, k, v, cfg, rng)
        fl = sla_flops(args.seq, dh, h, cfg)
        print(f"  kh={kh:.2f} sparsity={fl['sparsity']:.0%} "
              f"reduction={fl['reduction_x']:5.1f}x rel-L2 {err:.4f}")

    print("\n--- mode comparison at kh=0.10 ---")
    for mode in ("sla", "sparse_only", "linear_only", "l_plus_s"):
        cfg = base.replace(mode=mode)
        err = attention_fidelity(q, k, v, cfg, rng)
        print(f"  {mode:12s} rel-L2 error vs full: {err:.4f}")


if __name__ == "__main__":
    main()
