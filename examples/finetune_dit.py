"""End-to-end driver: pretrain a DiT with full attention, then fine-tune
with SLA (the paper's §5 workflow) and compare against the Table-2
ablation baselines (sparse-only / linear-only / L+S) at equal budget.

Defaults are CPU-runnable (~5M params); --preset 100m gives the ~100M
configuration for real hardware.

    PYTHONPATH=src python examples/finetune_dit.py \
        --pretrain-steps 150 --finetune-steps 150
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.config import SLAConfig
from repro.data.pipeline import DataConfig, latent_batch
from repro.models import dit
from repro.optim import adamw

PRESETS = {
    # ~5M — CPU-runnable demo
    "small": dict(num_layers=6, d_model=256, num_heads=4, head_dim=64,
                  d_ff=1024, seq=512, batch=4),
    # ~100M — the end-to-end scale from the deliverable (real hardware)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, head_dim=64,
                 d_ff=3072, seq=4096, batch=32),
}


def build(preset: str, mode: str) -> ArchConfig:
    p = PRESETS[preset]
    return ArchConfig(
        name=f"dit-{preset}", family="dit",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=0,
        patch_dim=16, cross_attn=False,
        attention_kind="full" if mode == "full" else "sla",
        sla=SLAConfig(block_q=32, block_kv=32, kh_frac=0.10, kl_frac=0.20,
                      phi="softmax", mode=mode if mode != "full" else "sla"),
    )


def train(cfg, params, shape, steps, lr, seed, sla_mode=None, log_every=25):
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=steps,
                                warmup_steps=max(steps // 10, 1),
                                schedule="cosine")
    opt = adamw.init(params)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: dit.loss_fn(p, cfg, batch, sla_mode=sla_mode))(params)
        params, opt, _ = adamw.update(params, grads, opt, opt_cfg)
        return params, opt, loss

    dc = DataConfig(seed=seed)
    hist = []
    for s in range(steps):
        batch = {k: jnp.asarray(v)
                 for k, v in latent_batch(cfg, shape, dc, s).items()}
        params, opt, loss = step_fn(params, opt, batch)
        hist.append(float(loss))
        if s % log_every == 0 or s == steps - 1:
            print(f"    step {s:4d} loss {float(loss):.5f}", flush=True)
    return params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--pretrain-steps", type=int, default=150)
    ap.add_argument("--finetune-steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--modes", default="sla,sparse_only,linear_only,l_plus_s")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    shape = ShapeConfig("dit", p["seq"], p["batch"], "train")
    rng = jax.random.PRNGKey(args.seed)

    # ---- phase A: "pretrain" with full attention
    cfg_full = build(args.preset, "full")
    params = dit.init(rng, cfg_full)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[pretrain] {n/1e6:.1f}M params, full attention, "
          f"{args.pretrain_steps} steps")
    t0 = time.time()
    params, hist = train(cfg_full, params, shape, args.pretrain_steps,
                         args.lr, args.seed)
    full_loss = sum(hist[-10:]) / len(hist[-10:])
    print(f"[pretrain] done in {time.time()-t0:.0f}s, "
          f"loss {full_loss:.5f}")

    # ---- phase B: fine-tune with each attention mode (paper §5 + Table 2)
    results = {"full_attention": full_loss}
    for mode in args.modes.split(","):
        cfg = build(args.preset, mode)
        print(f"[finetune:{mode}] {args.finetune_steps} steps")
        ft_params, hist = train(
            cfg, jax.tree.map(jnp.copy, params), shape,
            args.finetune_steps, args.lr * 0.5, args.seed + 1,
            sla_mode=mode)
        first = sum(hist[:5]) / 5
        final = sum(hist[-10:]) / len(hist[-10:])
        results[mode] = final
        print(f"[finetune:{mode}] first-5 {first:.5f} -> "
              f"final {final:.5f}")

    print("\n=== fine-tune quality (flow-matching loss; lower=better, "
          "full attention is the reference) ===")
    for k, v in sorted(results.items(), key=lambda kv: kv[1]):
        gap = v - results["full_attention"]
        print(f"  {k:16s} {v:.5f}  (gap {gap:+.5f})")
    order_ok = results.get("sla", 9e9) <= min(
        results.get("sparse_only", 9e9), results.get("linear_only", 9e9),
        results.get("l_plus_s", 9e9))
    print(f"\nSLA best among accelerated modes: {order_ok} "
          "(paper Table 2 ordering)")


if __name__ == "__main__":
    main()
